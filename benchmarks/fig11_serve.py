"""Paper Fig 11, serving edition: continuous-batching engine under
synthetic Poisson traffic, dense vs n:m:g FFN weights.

Drives ``repro.serve.ServeEngine`` with exponentially-distributed request
inter-arrival times and mixed prompt lengths, then writes the side-by-side
metrics (TTFT, p50/p99 per-token latency, throughput) to
``BENCH_serve.json`` — the machine-readable point the perf trajectory
tracks.  The headline number is ``sparse_over_dense_tok_p50``: < 1.0 means
the n:m:g decode path beats the dense baseline it serves against.

The run doubles as the decode-path integrity smoke for CI:

* the sparse serving run must not trace through the dense fallback on any
  projection (asserted via the dispatch registry counters), and
* the decode steps must route through the GEMV kernel path (asserted via
  the kernel routing counters).

With ``--table`` a ``repro.tune`` tuning table is loaded first, the
recorded ratio then reflects *tuned* routing, and the integrity checks
additionally require every routing decision to carry table provenance
(the CI ``tune-smoke`` job runs this mode against a freshly generated
table).

With ``--paged`` the benchmark instead measures the *capacity* story of
the paged KV cache: a slot-cache baseline at ``base_slots`` is served
against paged engines holding the **same KV memory**
(``num_pages = base_slots * max_seq_len / page_size``) at growing
concurrency multipliers.  Requests share a common prompt prefix
(``--shared-prefix-frac`` of the prompt, system-prompt-style traffic), so
prefix sharing lets the same pool hold many more concurrent requests.
Every engine serves the *same* request trace, so the comparison is
apples-to-apples.  On this single-core host a decode step's cost is
linear in the resident batch width (the XLA matmuls/attention sweep get
no extra parallelism), so the raw per-stream cadence necessarily grows
with concurrency for *any* cache organization; what the paged cache must
prove is that its machinery (gather/commit through the page table, CoW,
allocator bookkeeping) adds nothing on top.  The flatness criterion is
therefore the **concurrency-normalized steady-state TPOT** p99 —
per-token gaps excluding each stream's first gap (which spans the whole
co-arriving admission wave and is TTFT-territory scheduling latency,
reported separately), divided by the concurrency multiplier — which is
width-invariant for an overhead-free cache on a saturated core.  Raw
``summarize`` percentiles are recorded unmodified alongside.
The recorded ``sustainable_slots`` is the largest concurrency whose every
request reached a live slot (peak_active == max_slots, nothing rejected)
with normalized p99 within 1.2x of the slot baseline — and every paged
run's tokens are asserted identical to the slot-cache run of the same
requests (greedy decoding + slot isolation make them scheduling-
independent), with zero dense-fallback dispatches.

The model is a serving-scaled variant of the paper's BERT_BASE config:
wide enough (d_model 256, d_ff 4096) that the FFN projections the paper
sparsifies dominate the decode step, and sized so the n:m:g chunk extent
(m * C(m,n) * g) divides the projection K without padding waste.

    PYTHONPATH=src python -m benchmarks.fig11_serve [--quick]
"""

import argparse
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.ioutil import atomic_write_json
from repro.models import init_lm
from repro.obs import trace as obs
from repro.obs.export import phase_breakdown
from repro.obs.registry import REGISTRY, snapshot_diff
from repro.serve import FaultConfig, FaultInjector, Request, \
    SamplingParams, ServeEngine, SLOConfig, burst_arrivals, \
    compare_dense_sparse, sparsify_for_serving, trace_events
from repro.statutil import pct

disp = importlib.import_module("repro.core.dispatch")
kops = importlib.import_module("repro.kernels.ops")

# 1:4:8 => chunk extent m*C*g = 128, dividing both FFN K extents (256 and
# 4096) exactly — no compressed-K padding, so stored values = K/4 per fiber
NM = (1, 4, 8)
# row-sharing width: the kernels amortize their gathers across GR fibers
# and contract them as one dense tile (see sparsify_for_serving)
GR = 64
OUT_JSON = "BENCH_serve.json"


def serving_cfg():
    """Serving-scale smoke config: FFN-dominated decode, CPU-runnable.
    d_ff = 16 * d_model exaggerates BERT_BASE's 4x ratio so the
    projections the paper sparsifies carry most of the step FLOPs at this
    reduced width — the regime the full-size model is in anyway."""
    return get_smoke("bert-base-sten").scaled(
        dtype="float32", vocab=512, d_model=256, n_layers=2, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=4096,
    )


def poisson_requests(cfg, *, n_requests, rate_hz, prompt_lens, gen_len,
                     seed=0):
    """Synthetic trace: arrival gaps ~ Exp(rate), prompt lengths cycled."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab, jnp.int32
        ))
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=gen_len,
            sampling=SamplingParams(greedy=True, seed=i), arrival_time=t,
        ))
    return reqs


def _fallback_traces() -> dict:
    """Dense-fallback dispatch traces (should be empty for the sparse run)."""
    return {
        k: v for k, v in disp.dispatch_counters().items()
        if k[0] == "dense_fallback"
    }


def _check_decode_path(tuned: bool = False) -> dict:
    """Assert the sparse run's kernel-routing evidence; return it.

    Default routing must send decode steps through the GEMV path (the
    shipped heuristic).  With a tuning table loaded the direction is
    whatever the measurements said — the integrity requirements become
    that every projection still routed through a registered nmg path (no
    dense fallback) and that every routing decision actually came from
    the table (a quiet fallback to defaults here would silently unplug
    the tuner this job exists to exercise)."""
    fallbacks = _fallback_traces()
    if fallbacks:
        raise SystemExit(
            "fig11_serve: sparse serving traced through the dense fallback: "
            f"{fallbacks}"
        )
    kc = kops.kernel_counters()
    if tuned:
        routes = [k for k in kc if k[0] in ("nmg_linear", "nmg_matmul")]
        if not routes:
            raise SystemExit(
                f"fig11_serve: no routed nmg traces (kernel counters: {kc})"
            )
        untuned = [k for k in routes if not k[1].endswith("[table]")]
        if untuned:
            raise SystemExit(
                "fig11_serve: --table was given but these routing "
                f"decisions fell back to defaults: {untuned} — the table "
                "does not cover the serving shape buckets"
            )
    else:
        gemv = sum(v for (kern, _), v in kc.items() if kern == "nmg_gemv")
        if gemv == 0:
            raise SystemExit(
                "fig11_serve: no decode step routed to the nmg_gemv path "
                f"(kernel counters: {kc})"
            )
    return kc


def capacity_cfg():
    """Thin config for the paged *capacity* benchmark: narrow enough that
    a decode step is overhead-dominated on CPU, so widening the batch 8x
    moves per-token p99 by far less than 8x — the regime where the paged
    cache's extra concurrency is free and the measurement isolates
    capacity (pages) rather than arithmetic throughput."""
    return get_smoke("bert-base-sten").scaled(
        dtype="float32", vocab=512, d_model=128, n_layers=2, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=512,
    )


def shared_prefix_requests(cfg, *, n, prompt_len, shared_len, gen_len,
                           seed=0):
    """System-prompt-style trace: every prompt = one common ``shared_len``
    prefix + a per-request random suffix.  All arrivals at t=0 so the
    engine saturates to its concurrency limit immediately."""
    key = jax.random.PRNGKey(seed)
    prefix = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1 << 20), (shared_len,), 0, cfg.vocab,
        jnp.int32))
    reqs = []
    for i in range(n):
        suffix = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (prompt_len - shared_len,), 0,
            cfg.vocab, jnp.int32))
        reqs.append(Request(
            uid=i, prompt=np.concatenate([prefix, suffix]),
            max_new_tokens=gen_len,
            sampling=SamplingParams(greedy=True, seed=i), arrival_time=0.0,
        ))
    return reqs


def steady_tpot_p99(outs):
    """Time-per-output-token p99 in steady state: each stream's *first*
    inter-token gap spans the whole admission wave (co-arriving prefills)
    — that is scheduling latency, reported separately as TTFT — so it is
    excluded here, identically for every engine.  Unserved outputs
    (shed/timeout/rejected) carry no token times and contribute nothing."""
    gaps = []
    for o in outs:
        ts = o.token_times
        gaps.extend(b - a for a, b in zip(ts[1:-1], ts[2:]))
    return pct(gaps, 99)


def paged_main(quick=False, out_json=OUT_JSON, shared_prefix_frac=0.97):
    """--paged mode: slot-cache baseline vs paged engines at equal KV
    memory and growing concurrency; see the module docstring."""
    cfg = capacity_cfg()
    page_size = 4
    base_slots = 4
    prompt_len = 64 if quick else 128
    gen_len = 8
    max_seq = prompt_len + gen_len
    pages_per_slot = max_seq // page_size
    # exactly the slot baseline's KV bytes, repartitioned into pages
    num_pages = base_slots * pages_per_slot
    shared_len = max(0, int(prompt_len * shared_prefix_frac)
                     // page_size * page_size)
    mults = (1, 2, 4) if quick else (1, 2, 4, 8)
    n_total = base_slots * mults[-1]

    params = sparsify_for_serving(init_lm(jax.random.PRNGKey(0), cfg),
                                  *NM, gr=GR)
    disp.reset_dispatch_counters()
    kops.reset_kernel_counters()
    reqs = shared_prefix_requests(cfg, n=n_total, prompt_len=prompt_len,
                                  shared_len=shared_len, gen_len=gen_len)

    def warm(**ekw):
        # same widths/prompt length as the measured run -> the lru-cached
        # jitted closures are shared, so the measured engine never compiles
        ServeEngine(params, cfg, max_seq_len=max_seq, decode_chunk=gen_len,
                    **ekw).run(reqs[:2])

    warm(max_slots=base_slots)
    slot_eng = ServeEngine(params, cfg, max_slots=base_slots,
                           max_seq_len=max_seq, decode_chunk=gen_len)
    slot_outs = slot_eng.run(reqs)
    slot_by_uid = {o.uid: o.tokens for o in slot_outs}
    slot_met = slot_eng.metrics(label="slot")
    slot_steady_p99 = steady_tpot_p99(slot_outs)
    print("mode,slots,peak_active,tokens,tok_p50_ms,tok_p99_ms,tok_s")
    print(f"slot,{base_slots},{base_slots},{slot_met.num_tokens},"
          f"{slot_met.tok_latency_p50 * 1e3:.2f},"
          f"{slot_met.tok_latency_p99 * 1e3:.2f},"
          f"{slot_met.throughput_tok_s:.1f}")

    runs = []
    for m in mults:
        n = base_slots * m
        ekw = dict(max_slots=n, paged=True, page_size=page_size,
                   num_pages=num_pages)
        warm(**ekw)
        eng = ServeEngine(params, cfg, max_seq_len=max_seq,
                          decode_chunk=gen_len, **ekw)
        outs = eng.run(reqs)  # the full trace, same as the slot baseline
        met = eng.metrics(label=f"paged_x{m}")
        mismatched = [o.uid for o in outs if o.tokens != slot_by_uid[o.uid]]
        if mismatched:
            raise SystemExit(
                f"fig11_serve --paged: paged_x{m} tokens diverged from the "
                f"slot-cache run for uids {mismatched}"
            )
        steady = steady_tpot_p99(outs)
        runs.append({
            "multiplier": m,
            "max_slots": n,
            "peak_active": eng.stats["peak_active"],
            "preemptions": eng.stats["preemptions"],
            "deferred_admissions": eng.stats["deferred_admissions"],
            "rejected": eng.stats["rejected"],
            "steady_tpot_p99": steady,
            "steady_tpot_p99_per_slot_multiple": steady / m,
            "kv": dict(eng.kv.stats),
            **met.to_dict(),
        })
        print(f"paged_x{m},{n},{eng.stats['peak_active']},"
              f"{met.num_tokens},{met.tok_latency_p50 * 1e3:.2f},"
              f"{met.tok_latency_p99 * 1e3:.2f},"
              f"{met.throughput_tok_s:.1f}")

    fallbacks = _fallback_traces()
    if fallbacks:
        raise SystemExit(
            "fig11_serve --paged: sparse serving traced through the dense "
            f"fallback: {fallbacks}"
        )

    # Flatness gate: the concurrency-normalized steady-state TPOT p99
    # (see module docstring) must stay within 1.2x the slot baseline —
    # i.e. the paging machinery itself adds <20% on top of the
    # unavoidable width scaling of a single-core decode step.
    p99_cap = 1.2 * slot_steady_p99
    sustained = [r for r in runs
                 if r["peak_active"] == r["max_slots"]
                 and r["rejected"] == 0
                 and r["steady_tpot_p99_per_slot_multiple"] <= p99_cap]
    best = max(sustained, key=lambda r: r["max_slots"]) if sustained else None
    section = {
        "config": {
            "arch": "bert-base-sten(capacity-smoke)",
            "d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers, "nm": ":".join(map(str, NM)),
            "page_size": page_size, "num_pages": num_pages,
            "base_slots": base_slots, "max_seq_len": max_seq,
            "prompt_len": prompt_len, "shared_prefix_len": shared_len,
            "shared_prefix_frac": shared_prefix_frac, "gen_len": gen_len,
            "quick": bool(quick),
        },
        "slot_baseline": {**slot_met.to_dict(),
                          "steady_tpot_p99": slot_steady_p99},
        "runs": runs,
        "sustainable_slots": best["max_slots"] if best else 0,
        "concurrency_multiplier_vs_slot":
            (best["max_slots"] / base_slots) if best else 0.0,
        "p99_ratio_at_sustainable":
            (best["steady_tpot_p99_per_slot_multiple"] / slot_steady_p99)
            if best and slot_steady_p99 > 0 else float("nan"),
        "p99_metric": "steady-state TPOT p99 / multiplier vs slot "
                      "baseline (first gap per stream = admission wave, "
                      "excluded for both engines; single-core host makes "
                      "raw cadence width-linear for any cache — see "
                      "module docstring)",
        "token_equivalence_with_slot_cache": True,
        "dense_fallback_traces": 0,
    }
    try:
        with open(out_json) as f:
            payload = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        payload = {"benchmark": "fig11_serve"}
    payload["paged"] = section
    atomic_write_json(out_json, payload)
    print(f"sustainable_slots: {section['sustainable_slots']} "
          f"({section['concurrency_multiplier_vs_slot']:.0f}x slot cache "
          f"at equal KV memory, p99 ratio "
          f"{section['p99_ratio_at_sustainable']:.2f})")
    print(f"wrote {out_json}")


def slo_requests(cfg, *, arrivals, prompt_lens, gen_lens, priorities,
                 deadline_s, seed=0):
    """Bursty overload trace: one request per arrival time, prompt/gen
    lengths and priorities cycled by index.  Most requests share the
    short gen length; every fourth is a *long-runner* that stays resident
    across several admission waves — the stream whose mid-flight token
    gaps expose what each admission policy costs the already-running
    work."""
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i, t in enumerate(arrivals):
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab, jnp.int32))
        reqs.append(Request(
            uid=i, prompt=prompt,
            max_new_tokens=gen_lens[i % len(gen_lens)],
            sampling=SamplingParams(greedy=True, seed=i),
            arrival_time=float(t),
            priority=priorities[i % len(priorities)],
            deadline_s=deadline_s,
        ))
    return reqs


def _warm_plain(params, cfg, *, plens, chunk, ekw):
    """Compile the plain engine's programs (per-plen prefill, decode,
    chunk) via a throwaway engine sharing the module-level jit caches."""
    reqs = [Request(uid=-1 - i, prompt=np.arange(1, p + 1) % 7 + 1,
                    max_new_tokens=chunk + 1,
                    sampling=SamplingParams(greedy=True, seed=i))
            for i, p in enumerate(sorted(set(plens)))]
    ServeEngine(params, cfg, decode_chunk=chunk, **ekw).run(reqs)


def _obs_probe(params, cfg, *, ekw, chunk, prompt_lens, gen_len):
    """Tracing-cost probe: serve one small identical request trace with
    the flight recorder off, then on, through freshly built plain engines
    (all programs already compiled by the earlier warmup, so both runs
    are steady-state).  Returns ``(tokens_identical, p50_overhead,
    p50_by_mode)`` — greedy decoding plus host-side-only instrumentation
    make the token streams bitwise-identical by construction, and the
    acceptance story asserts exactly that.  Each mode takes the best of
    two runs so the overhead estimate is not one background-load spike."""
    probe_reqs = poisson_requests(cfg, n_requests=6, rate_hz=50.0,
                                  prompt_lens=prompt_lens, gen_len=gen_len,
                                  seed=17)
    was_on = obs.enabled()
    toks, p50 = {}, {}
    for mode in ("off", "on"):
        (obs.enable if mode == "on" else obs.disable)()
        best = float("inf")
        for _ in range(2):
            eng = ServeEngine(params, cfg, decode_chunk=chunk, **ekw)
            outs = eng.run(probe_reqs)
            met = eng.metrics(label=f"obs_{mode}")
            best = min(best, met.tok_latency_p50)
        toks[mode] = {o.uid: o.tokens for o in outs}
        p50[mode] = best
    (obs.enable if was_on else obs.disable)()
    overhead = ((p50["on"] - p50["off"]) / p50["off"]
                if p50["off"] > 0 else float("nan"))
    return toks["off"] == toks["on"], overhead, p50


def slo_main(quick=False, out_json=OUT_JSON, faults=True, trace_path=None):
    """--bursty mode: SLO-controlled engine (adaptive sparsity tiers,
    deferred admissions, load shedding) vs the uncontrolled engine under
    the *same* bursty arrival trace and (with --faults) the same seeded
    fault schedule.

    The SLO itself is calibrated on this host: a healthy run (gentle
    Poisson arrivals, no faults, dense weights) measures the steady-state
    TPOT p99 the hardware delivers when never overloaded, and the SLO is
    ``SLO_MARGIN`` times that.  The gates then assert the paper's
    overload story end-to-end:

    * controlled steady-state TPOT p99 <= SLO,
    * controlled shed-rate < ``SHED_RATE_MAX``,
    * uncontrolled steady-state TPOT p99 >= ``UNCTRL_FACTOR`` * SLO,
    * zero recompiles after ``warm_tiers`` (tier switches and chunk
      shrinks are pointer swaps into already-compiled executables).

    The contrast mechanism on this single-core host: an admission
    prefill stalls every resident stream, so the uncontrolled engine's
    back-to-back admission waves (every free slot refilled at once, at
    dense prefill cost) inject multi-prefill gaps into the long-runners'
    token cadence, while the controlled engine rations admissions to one
    per step, switches to the cheaper sparse tier, and sheds the queue
    tail instead of paying for it."""
    SLO_MARGIN = 1.5       # SLO = margin * healthy steady p99
    UNCTRL_FACTOR = 2.0    # uncontrolled must exceed this * SLO
    SHED_RATE_MAX = 0.20

    cfg = serving_cfg()
    max_slots = 6
    base_chunk = 8
    prompt_lens = (16, 12, 8) if quick else (32, 24, 16)
    gen_short = 12 if quick else 16
    gen_long = 4 * gen_short
    # a rare long-runner among cohorts of shorts: the cohort finishes
    # together, so the uncontrolled engine refills its slots in one wave
    # while a long-runner is mid-stream — the gap the controlled engine's
    # admission rationing avoids.  Longs are kept rare (1 in 9) so they
    # do not accumulate into the slots and narrow the waves.
    gen_lens = (gen_short,) * 8 + (gen_long,)
    max_seq = max(prompt_lens) + gen_long
    n_bg = 8 if quick else 16
    burst_size = 14 if quick else 20
    arrivals = burst_arrivals(
        n_background=n_bg, rate_hz=20.0,
        bursts=((0.05, burst_size), (1.5, burst_size)), seed=7)
    n_total = len(arrivals)
    # uniform priority: admission order then stays FIFO, so the 4:2
    # cohort cycle survives into the slots (mixed priorities reorder the
    # queue and destagger the cohorts; priority-typed shedding is
    # exercised by the unit and fault-storm tests)
    reqs = slo_requests(cfg, arrivals=arrivals, prompt_lens=prompt_lens,
                        gen_lens=gen_lens, priorities=(0,),
                        deadline_s=120.0)

    tier_specs = ["dense", f"{':'.join(map(str, NM))}-gr{GR}"]
    fcfg = FaultConfig(
        seed=11, horizon=4096,
        spike_prob=0.03 if faults else 0.0, spike_s=(0.002, 0.008),
        slow_windows=((24, 60, 4.0),) if faults else (),
        error_prob=0.04 if faults else 0.0, max_consecutive_errors=2,
        admission_delay_s=0.03 if faults else 0.0,
    )

    params = init_lm(jax.random.PRNGKey(0), cfg)
    disp.reset_dispatch_counters()
    kops.reset_kernel_counters()
    ekw = dict(max_slots=max_slots, max_seq_len=max_seq)
    if trace_path:
        # on before any compile: the trace then carries the kernel-route
        # and jit-trace events the dispatch/kernel registries emit at
        # trace time, alongside the serving lifecycle spans
        obs.enable()

    # -- calibration: what does "healthy" look like on this host? ---------
    # Moderate (non-overloaded) load on the same engine and the same
    # per-admission infrastructure tax, but none of the injected faults:
    # the healthy distribution then includes the occasional *single*
    # admission stall that normal slot churn costs resident streams, so
    # the SLO derived from it budgets for the system as deployed rather
    # than a fault-free idealization.
    _warm_plain(params, cfg, plens=prompt_lens, chunk=base_chunk, ekw=ekw)
    healthy_reqs = poisson_requests(
        cfg, n_requests=2 * max_slots, rate_hz=5.0,
        prompt_lens=prompt_lens, gen_len=gen_short, seed=3)
    healthy_faults = FaultInjector(FaultConfig(
        seed=fcfg.seed, admission_delay_s=fcfg.admission_delay_s)) \
        if faults else None
    healthy_eng = ServeEngine(params, cfg, decode_chunk=base_chunk,
                              faults=healthy_faults, **ekw)
    healthy_outs = healthy_eng.run(healthy_reqs)
    healthy_p99 = steady_tpot_p99(healthy_outs)
    slo_s = SLO_MARGIN * healthy_p99
    slo = SLOConfig(tpot_ms=slo_s * 1e3, queue_keep_per_slot=5.0,
                    queue_high_per_slot=3.0)

    # -- controlled: tiers + SLO control loop + fault injection -----------
    ctrl = ServeEngine(params, cfg, decode_chunk=base_chunk, slo=slo,
                       tiers=tier_specs,
                       faults=FaultInjector(fcfg) if faults else None,
                       **ekw)
    ctrl.warm_tiers(prompt_lens=prompt_lens)
    traces_before = trace_events()
    reg_before = REGISTRY.snapshot()
    ctrl_outs = ctrl.run(reqs)
    reg_diff = snapshot_diff(reg_before, REGISTRY.snapshot())
    traces_after = trace_events()
    recompiled = {k: traces_after[k] - traces_before.get(k, 0)
                  for k in traces_after
                  if traces_after[k] != traces_before.get(k, 0)}
    if recompiled:
        obs.postmortem("fig11_recompile_after_warm_tiers")
        raise SystemExit(
            "fig11_serve --bursty: the controlled engine recompiled after "
            f"warm_tiers (trace deltas: {recompiled}) — tier switches "
            "must be pointer swaps into already-compiled executables"
        )
    ctrl_met = ctrl.metrics(label="controlled")
    ctrl_p99 = steady_tpot_p99(ctrl_outs)
    shed_rate = ctrl.stats["shed"] / n_total

    # -- uncontrolled: same trace, same fault schedule, no control loop ---
    unctrl = ServeEngine(params, cfg, decode_chunk=base_chunk,
                         faults=FaultInjector(fcfg) if faults else None,
                         **ekw)
    unctrl_outs = unctrl.run(reqs)
    unctrl_met = unctrl.metrics(label="uncontrolled")
    unctrl_p99 = steady_tpot_p99(unctrl_outs)

    fallbacks = _fallback_traces()
    if fallbacks:
        obs.postmortem("fig11_dense_fallback")
        raise SystemExit(
            "fig11_serve --bursty: sparse tier traced through the dense "
            f"fallback: {fallbacks}"
        )

    # -- tracing cost + equivalence: same trace, recorder off vs on -------
    tokens_equal, obs_overhead, obs_p50 = _obs_probe(
        params, cfg, ekw=ekw, chunk=base_chunk, prompt_lens=prompt_lens,
        gen_len=gen_short)

    print("mode,served,shed,timeout,steady_p99_ms,p99_over_slo")
    for label, met, p99, stats in (
            ("controlled", ctrl_met, ctrl_p99, ctrl.stats),
            ("uncontrolled", unctrl_met, unctrl_p99, unctrl.stats)):
        print(f"{label},{met.num_requests},{stats['shed']},"
              f"{stats['timeout']},{p99 * 1e3:.1f},{p99 / slo_s:.2f}")
    print(f"slo_tpot_ms: {slo_s * 1e3:.1f} "
          f"(= {SLO_MARGIN:.1f} x healthy steady p99 "
          f"{healthy_p99 * 1e3:.1f} ms)")
    print(f"controlled: tier_switches={ctrl.stats['tier_switches']} "
          f"shed_rate={shed_rate:.1%} "
          f"fault_retries={ctrl.stats['fault_retries']} "
          f"slo_attainment={ctrl_met.slo_attainment:.2f} "
          f"controller={ctrl._controller.counters}")

    gates = {
        "controlled_p99_within_slo": bool(ctrl_p99 <= slo_s),
        "shed_rate_below_max": bool(shed_rate < SHED_RATE_MAX),
        # greedy decode + host-side-only instrumentation: recording the
        # flight of a request must never change its tokens
        "token_equivalence_tracing": bool(tokens_equal),
    }
    if faults:
        # the >= 2x-SLO overload contrast is the *fault-injected* story
        # (slow-decode windows + per-admission delays amplify what the
        # uncontrolled admission waves cost); without --faults the burst
        # alone is a milder overload and only the controlled-side gates
        # are asserted — the ratio is still recorded either way
        gates["uncontrolled_p99_exceeds_2x_slo"] = \
            bool(unctrl_p99 >= UNCTRL_FACTOR * slo_s)
    section = {
        "config": {
            "arch": "bert-base-sten(serving-smoke)",
            "tiers": tier_specs, "max_slots": max_slots,
            "decode_chunk": base_chunk, "n_requests": n_total,
            "prompt_lens": list(prompt_lens), "gen_lens": list(gen_lens),
            "bursts": [[0.05, burst_size], [1.5, burst_size]],
            "faults": bool(faults),
            "fault_config": {
                "seed": fcfg.seed, "spike_prob": fcfg.spike_prob,
                "slow_windows": [list(w) for w in fcfg.slow_windows],
                "error_prob": fcfg.error_prob,
                "admission_delay_s": fcfg.admission_delay_s,
            },
            "quick": bool(quick),
        },
        "healthy_steady_tpot_p99": healthy_p99,
        "slo_margin": SLO_MARGIN,
        "slo_tpot_ms": slo_s * 1e3,
        "controlled": {
            **ctrl_met.to_dict(), "steady_tpot_p99": ctrl_p99,
            "p99_over_slo": ctrl_p99 / slo_s, "shed_rate": shed_rate,
            "stats": dict(ctrl.stats),
            "controller": dict(ctrl._controller.counters),
            "tokens_by_tier": dict(ctrl.tokens_by_tier),
        },
        "uncontrolled": {
            **unctrl_met.to_dict(), "steady_tpot_p99": unctrl_p99,
            "p99_over_slo": unctrl_p99 / slo_s,
            "stats": dict(unctrl.stats),
        },
        "recompile_free_after_warmup": True,
        "gates": gates,
    }
    obs_section = {
        "traced": bool(trace_path),
        "trace_path": trace_path,
        "trace_events": len(obs.records()),
        "dropped_records": obs.dropped(),
        # wall-clock accounting of the controlled run by span name (plus
        # the probe's own spans when --trace is on)
        "phase_breakdown": phase_breakdown(obs.records()),
        # registry deltas across exactly the controlled run: engine
        # scheduler counters, SLO decisions, injected faults, jit traces
        "registry_diff_controlled": reg_diff,
        # end-of-run state of every registered instrument (all modes)
        "registry": REGISTRY.snapshot(),
        "token_equivalence_tracing": bool(tokens_equal),
        "decode_p50_overhead_tracing": obs_overhead,
        "probe_tok_p50_ms": {m: v * 1e3 for m, v in obs_p50.items()},
    }
    try:
        with open(out_json) as f:
            payload = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        payload = {"benchmark": "fig11_serve"}
    payload["slo"] = section
    payload["obs"] = obs_section
    atomic_write_json(out_json, payload)
    print(f"wrote {out_json}")
    if trace_path:
        obs.dump(trace_path, registry_snapshot=REGISTRY.snapshot())
        print(f"wrote {trace_path} ({len(obs.records())} events, "
              f"{obs.dropped()} dropped) — open in ui.perfetto.dev")
    print(f"obs: tracing tok_p50 overhead "
          f"{obs_overhead:+.1%} (off {obs_p50['off'] * 1e3:.2f} ms, "
          f"on {obs_p50['on'] * 1e3:.2f} ms), tokens identical: "
          f"{tokens_equal}")
    failed = [k for k, ok in gates.items() if not ok]
    if failed:
        obs.postmortem("fig11_slo_gates_failed")
        raise SystemExit(
            f"fig11_serve --bursty: SLO gates failed: {failed} "
            f"(slo={slo_s * 1e3:.1f}ms controlled={ctrl_p99 * 1e3:.1f}ms "
            f"uncontrolled={unctrl_p99 * 1e3:.1f}ms "
            f"shed_rate={shed_rate:.1%})"
        )
    print(f"gates passed: controlled p99 {ctrl_p99 / slo_s:.2f}x SLO, "
          f"uncontrolled {unctrl_p99 / slo_s:.2f}x SLO, "
          f"shed rate {shed_rate:.1%}")


def main(quick=False, out_json=OUT_JSON, table=None):
    from repro.tune import load_table_cli

    # explicit --table only: this benchmark's integrity gates differ
    # between tuned and untuned routing, so a stray $REPRO_TUNE_TABLE in
    # the environment must not silently flip the run's mode
    try:
        tuning = load_table_cli(table) if table else None
    except ValueError as e:
        # a corrupt/stale --table must abort, not silently benchmark the
        # untuned defaults while labelling the run tuned
        raise SystemExit(f"fig11_serve: {e}")
    if tuning is not None and len(tuning) == 0:
        # distinguish "no section for this device" from the
        # missing-shape-buckets abort the provenance gate would raise
        raise SystemExit(
            f"fig11_serve: {table} has no entries for device "
            f"{tuning.device} — generate one here with "
            f"`python -m repro.tune --quick --out {table}`"
        )
    cfg = serving_cfg()
    # enough decode chunks that the p50 token gap is a stable statistic
    # (each chunk contributes decode_chunk near-identical gaps)
    n_requests = 12 if quick else 24
    gen_len = 16 if quick else 32
    prompt_lens = (16, 12, 8) if quick else (32, 24, 16)
    rate_hz = 200.0  # arrivals far faster than decode => queueing pressure
    max_slots = 4
    max_seq = max(prompt_lens) + gen_len
    ekw = dict(max_slots=max_slots, max_seq_len=max_seq)

    reqs = poisson_requests(cfg, n_requests=n_requests, rate_hz=rate_hz,
                            prompt_lens=prompt_lens, gen_len=gen_len)

    params = init_lm(jax.random.PRNGKey(0), cfg)
    disp.reset_dispatch_counters()
    kops.reset_kernel_counters()
    # warmup=True: measure steady-state serving, not compile stalls.  The
    # trace is served ``repeats`` times per mode and each mode reports its
    # best (min tok_p50) run — the standard steady-state estimate, robust
    # to one mode eating a background-load spike the other didn't.
    repeats = 3 if quick else 4
    results = None
    for _ in range(repeats):
        run = compare_dense_sparse(params, cfg, reqs, nm=NM, gr=GR,
                                   engine_kwargs=ekw, warmup=results is None)
        if results is None:
            results = run
        else:
            for label, (outs, met) in run.items():
                if met.tok_latency_p50 < results[label][1].tok_latency_p50:
                    results[label] = (outs, met)
    kernel_paths = _check_decode_path(tuned=tuning is not None)

    print("mode,requests,tokens,ttft_p50_ms,tok_p50_ms,tok_p99_ms,tok_s")
    payload = {
        "benchmark": "fig11_serve",
        "config": {
            "arch": "bert-base-sten(serving-smoke)",
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers,
            "nm": ":".join(map(str, NM)),
            "n_requests": n_requests,
            "gen_len": gen_len,
            "prompt_lens": list(prompt_lens),
            "rate_hz": rate_hz,
            "max_slots": max_slots,
            "quick": bool(quick),
        },
        # trace-time routing evidence: every sparse projection dispatched
        # to a registered nmg kernel, decode steps took the GEMV path; the
        # ("nmg_linear", "<path>[table|default]") entries show whether the
        # routing decisions came from a tuning table or shipped defaults
        "kernel_paths": {"/".join(k): v for k, v in kernel_paths.items()},
        "dense_fallback_traces": 0,
        "tuning_table": table or None,
        "tuning_entries": len(tuning) if tuning is not None else 0,
    }
    for label, (outs, met) in results.items():
        payload[label] = met.to_dict()
        print(f"{label},{met.num_requests},{met.num_tokens},"
              f"{met.ttft_p50 * 1e3:.1f},{met.tok_latency_p50 * 1e3:.2f},"
              f"{met.tok_latency_p99 * 1e3:.2f},"
              f"{met.throughput_tok_s:.1f}")
    d, s = payload["dense"], payload["sparse"]
    if d["tok_latency_p50"] > 0:
        payload["sparse_over_dense_tok_p50"] = (
            s["tok_latency_p50"] / d["tok_latency_p50"]
        )
        print(f"sparse_over_dense_tok_p50: "
              f"{payload['sparse_over_dense_tok_p50']:.3f}")
    try:
        with open(out_json) as f:
            prev = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        prev = {}
    for section in ("paged", "slo", "obs"):
        if section in prev:
            # --paged / --bursty results live in their own sections; a
            # dense-vs-sparse rerun refreshes its numbers without
            # discarding them
            payload[section] = prev[section]
    atomic_write_json(out_json, payload)
    print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--table", default=None, metavar="PATH",
                    help="load a repro.tune tuning table before serving, "
                         "so the recorded ratio reflects tuned routing")
    ap.add_argument("--paged", action="store_true",
                    help="run the paged-KV-cache capacity benchmark "
                         "(slot baseline vs paged engines at equal KV "
                         "memory) instead of dense-vs-sparse")
    ap.add_argument("--shared-prefix-frac", type=float, default=0.97,
                    metavar="F",
                    help="fraction of each prompt that is a common shared "
                         "prefix in the --paged trace (default 0.97)")
    ap.add_argument("--bursty", action="store_true",
                    help="run the SLO overload benchmark: controlled "
                         "engine (tiers + control loop) vs uncontrolled "
                         "under the same bursty arrival trace")
    ap.add_argument("--faults", action="store_true",
                    help="with --bursty, inject the seeded fault schedule "
                         "(latency spikes, slow-decode windows, transient "
                         "errors, admission delays) into both engines")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --bursty, export the run's flight recorder "
                         "as Chrome/Perfetto trace JSON (request lifecycle "
                         "spans, controller decisions, fault injections, "
                         "kernel routes) — open in ui.perfetto.dev")
    args = ap.parse_args()
    if args.faults and not args.bursty:
        ap.error("--faults requires --bursty")
    if args.trace and not args.bursty:
        ap.error("--trace requires --bursty")
    if args.bursty and args.paged:
        ap.error("--bursty and --paged are separate modes")
    if args.bursty:
        slo_main(quick=args.quick, faults=args.faults,
                 trace_path=args.trace)
    elif args.paged:
        paged_main(quick=args.quick,
                   shared_prefix_frac=args.shared_prefix_frac)
    else:
        main(quick=args.quick, table=args.table)

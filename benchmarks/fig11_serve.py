"""Paper Fig 11, serving edition: continuous-batching engine under
synthetic Poisson traffic, dense vs n:m:g FFN weights.

Drives ``repro.serve.ServeEngine`` with exponentially-distributed request
inter-arrival times and mixed prompt lengths, then writes the side-by-side
metrics (TTFT, p50/p99 per-token latency, throughput) to
``BENCH_serve.json`` — the machine-readable point the perf trajectory
tracks.

    PYTHONPATH=src python -m benchmarks.fig11_serve [--quick]
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import init_lm
from repro.serve import Request, SamplingParams, compare_dense_sparse

NM = (1, 4, 16)
OUT_JSON = "BENCH_serve.json"


def poisson_requests(cfg, *, n_requests, rate_hz, prompt_lens, gen_len,
                     seed=0):
    """Synthetic trace: arrival gaps ~ Exp(rate), prompt lengths cycled."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab, jnp.int32
        ))
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=gen_len,
            sampling=SamplingParams(greedy=True, seed=i), arrival_time=t,
        ))
    return reqs


def main(quick=False, out_json=OUT_JSON):
    cfg = get_smoke("bert-base-sten").scaled(dtype="float32")
    n_requests = 8 if quick else 24
    gen_len = 8 if quick else 16
    prompt_lens = (16, 12, 8) if quick else (32, 24, 16)
    rate_hz = 200.0  # arrivals far faster than decode => queueing pressure
    max_slots = 4
    max_seq = max(prompt_lens) + gen_len
    ekw = dict(max_slots=max_slots, max_seq_len=max_seq)

    reqs = poisson_requests(cfg, n_requests=n_requests, rate_hz=rate_hz,
                            prompt_lens=prompt_lens, gen_len=gen_len)

    params = init_lm(jax.random.PRNGKey(0), cfg)
    # warmup=True: measure steady-state serving, not compile stalls
    results = compare_dense_sparse(params, cfg, reqs, nm=NM,
                                   engine_kwargs=ekw, warmup=True)

    print("mode,requests,tokens,ttft_p50_ms,tok_p50_ms,tok_p99_ms,tok_s")
    payload = {
        "benchmark": "fig11_serve",
        "config": {
            "arch": "bert-base-sten(smoke)",
            "nm": ":".join(map(str, NM)),
            "n_requests": n_requests,
            "gen_len": gen_len,
            "prompt_lens": list(prompt_lens),
            "rate_hz": rate_hz,
            "max_slots": max_slots,
            "quick": bool(quick),
        },
    }
    for label, (outs, met) in results.items():
        payload[label] = met.to_dict()
        print(f"{label},{met.num_requests},{met.num_tokens},"
              f"{met.ttft_p50 * 1e3:.1f},{met.tok_latency_p50 * 1e3:.2f},"
              f"{met.tok_latency_p99 * 1e3:.2f},"
              f"{met.throughput_tok_s:.1f}")
    d, s = payload["dense"], payload["sparse"]
    if d["tok_latency_p50"] > 0:
        payload["sparse_over_dense_tok_p50"] = (
            s["tok_latency_p50"] / d["tok_latency_p50"]
        )
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)

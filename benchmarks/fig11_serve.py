"""Paper Fig 11, serving edition: continuous-batching engine under
synthetic Poisson traffic, dense vs n:m:g FFN weights.

Drives ``repro.serve.ServeEngine`` with exponentially-distributed request
inter-arrival times and mixed prompt lengths, then writes the side-by-side
metrics (TTFT, p50/p99 per-token latency, throughput) to
``BENCH_serve.json`` — the machine-readable point the perf trajectory
tracks.  The headline number is ``sparse_over_dense_tok_p50``: < 1.0 means
the n:m:g decode path beats the dense baseline it serves against.

The run doubles as the decode-path integrity smoke for CI:

* the sparse serving run must not trace through the dense fallback on any
  projection (asserted via the dispatch registry counters), and
* the decode steps must route through the GEMV kernel path (asserted via
  the kernel routing counters).

With ``--table`` a ``repro.tune`` tuning table is loaded first, the
recorded ratio then reflects *tuned* routing, and the integrity checks
additionally require every routing decision to carry table provenance
(the CI ``tune-smoke`` job runs this mode against a freshly generated
table).

The model is a serving-scaled variant of the paper's BERT_BASE config:
wide enough (d_model 256, d_ff 4096) that the FFN projections the paper
sparsifies dominate the decode step, and sized so the n:m:g chunk extent
(m * C(m,n) * g) divides the projection K without padding waste.

    PYTHONPATH=src python -m benchmarks.fig11_serve [--quick]
"""

import argparse
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import init_lm
from repro.serve import Request, SamplingParams, compare_dense_sparse

disp = importlib.import_module("repro.core.dispatch")
kops = importlib.import_module("repro.kernels.ops")

# 1:4:8 => chunk extent m*C*g = 128, dividing both FFN K extents (256 and
# 4096) exactly — no compressed-K padding, so stored values = K/4 per fiber
NM = (1, 4, 8)
# row-sharing width: the kernels amortize their gathers across GR fibers
# and contract them as one dense tile (see sparsify_for_serving)
GR = 64
OUT_JSON = "BENCH_serve.json"


def serving_cfg():
    """Serving-scale smoke config: FFN-dominated decode, CPU-runnable.
    d_ff = 16 * d_model exaggerates BERT_BASE's 4x ratio so the
    projections the paper sparsifies carry most of the step FLOPs at this
    reduced width — the regime the full-size model is in anyway."""
    return get_smoke("bert-base-sten").scaled(
        dtype="float32", vocab=512, d_model=256, n_layers=2, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=4096,
    )


def poisson_requests(cfg, *, n_requests, rate_hz, prompt_lens, gen_len,
                     seed=0):
    """Synthetic trace: arrival gaps ~ Exp(rate), prompt lengths cycled."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab, jnp.int32
        ))
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=gen_len,
            sampling=SamplingParams(greedy=True, seed=i), arrival_time=t,
        ))
    return reqs


def _fallback_traces() -> dict:
    """Dense-fallback dispatch traces (should be empty for the sparse run)."""
    return {
        k: v for k, v in disp.dispatch_counters().items()
        if k[0] == "dense_fallback"
    }


def _check_decode_path(tuned: bool = False) -> dict:
    """Assert the sparse run's kernel-routing evidence; return it.

    Default routing must send decode steps through the GEMV path (the
    shipped heuristic).  With a tuning table loaded the direction is
    whatever the measurements said — the integrity requirements become
    that every projection still routed through a registered nmg path (no
    dense fallback) and that every routing decision actually came from
    the table (a quiet fallback to defaults here would silently unplug
    the tuner this job exists to exercise)."""
    fallbacks = _fallback_traces()
    if fallbacks:
        raise SystemExit(
            "fig11_serve: sparse serving traced through the dense fallback: "
            f"{fallbacks}"
        )
    kc = kops.kernel_counters()
    if tuned:
        routes = [k for k in kc if k[0] in ("nmg_linear", "nmg_matmul")]
        if not routes:
            raise SystemExit(
                f"fig11_serve: no routed nmg traces (kernel counters: {kc})"
            )
        untuned = [k for k in routes if not k[1].endswith("[table]")]
        if untuned:
            raise SystemExit(
                "fig11_serve: --table was given but these routing "
                f"decisions fell back to defaults: {untuned} — the table "
                "does not cover the serving shape buckets"
            )
    else:
        gemv = sum(v for (kern, _), v in kc.items() if kern == "nmg_gemv")
        if gemv == 0:
            raise SystemExit(
                "fig11_serve: no decode step routed to the nmg_gemv path "
                f"(kernel counters: {kc})"
            )
    return kc


def main(quick=False, out_json=OUT_JSON, table=None):
    from repro.tune import load_table_cli

    # explicit --table only: this benchmark's integrity gates differ
    # between tuned and untuned routing, so a stray $REPRO_TUNE_TABLE in
    # the environment must not silently flip the run's mode
    tuning = load_table_cli(table) if table else None
    if tuning is not None and len(tuning) == 0:
        # distinguish "no section for this device" from the
        # missing-shape-buckets abort the provenance gate would raise
        raise SystemExit(
            f"fig11_serve: {table} has no entries for device "
            f"{tuning.device} — generate one here with "
            f"`python -m repro.tune --quick --out {table}`"
        )
    cfg = serving_cfg()
    # enough decode chunks that the p50 token gap is a stable statistic
    # (each chunk contributes decode_chunk near-identical gaps)
    n_requests = 12 if quick else 24
    gen_len = 16 if quick else 32
    prompt_lens = (16, 12, 8) if quick else (32, 24, 16)
    rate_hz = 200.0  # arrivals far faster than decode => queueing pressure
    max_slots = 4
    max_seq = max(prompt_lens) + gen_len
    ekw = dict(max_slots=max_slots, max_seq_len=max_seq)

    reqs = poisson_requests(cfg, n_requests=n_requests, rate_hz=rate_hz,
                            prompt_lens=prompt_lens, gen_len=gen_len)

    params = init_lm(jax.random.PRNGKey(0), cfg)
    disp.reset_dispatch_counters()
    kops.reset_kernel_counters()
    # warmup=True: measure steady-state serving, not compile stalls.  The
    # trace is served ``repeats`` times per mode and each mode reports its
    # best (min tok_p50) run — the standard steady-state estimate, robust
    # to one mode eating a background-load spike the other didn't.
    repeats = 3 if quick else 4
    results = None
    for _ in range(repeats):
        run = compare_dense_sparse(params, cfg, reqs, nm=NM, gr=GR,
                                   engine_kwargs=ekw, warmup=results is None)
        if results is None:
            results = run
        else:
            for label, (outs, met) in run.items():
                if met.tok_latency_p50 < results[label][1].tok_latency_p50:
                    results[label] = (outs, met)
    kernel_paths = _check_decode_path(tuned=tuning is not None)

    print("mode,requests,tokens,ttft_p50_ms,tok_p50_ms,tok_p99_ms,tok_s")
    payload = {
        "benchmark": "fig11_serve",
        "config": {
            "arch": "bert-base-sten(serving-smoke)",
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers,
            "nm": ":".join(map(str, NM)),
            "n_requests": n_requests,
            "gen_len": gen_len,
            "prompt_lens": list(prompt_lens),
            "rate_hz": rate_hz,
            "max_slots": max_slots,
            "quick": bool(quick),
        },
        # trace-time routing evidence: every sparse projection dispatched
        # to a registered nmg kernel, decode steps took the GEMV path; the
        # ("nmg_linear", "<path>[table|default]") entries show whether the
        # routing decisions came from a tuning table or shipped defaults
        "kernel_paths": {"/".join(k): v for k, v in kernel_paths.items()},
        "dense_fallback_traces": 0,
        "tuning_table": table or None,
        "tuning_entries": len(tuning) if tuning is not None else 0,
    }
    for label, (outs, met) in results.items():
        payload[label] = met.to_dict()
        print(f"{label},{met.num_requests},{met.num_tokens},"
              f"{met.ttft_p50 * 1e3:.1f},{met.tok_latency_p50 * 1e3:.2f},"
              f"{met.tok_latency_p99 * 1e3:.2f},"
              f"{met.throughput_tok_s:.1f}")
    d, s = payload["dense"], payload["sparse"]
    if d["tok_latency_p50"] > 0:
        payload["sparse_over_dense_tok_p50"] = (
            s["tok_latency_p50"] / d["tok_latency_p50"]
        )
        print(f"sparse_over_dense_tok_p50: "
              f"{payload['sparse_over_dense_tok_p50']:.3f}")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--table", default=None, metavar="PATH",
                    help="load a repro.tune tuning table before serving, "
                         "so the recorded ratio reflects tuned routing")
    args = ap.parse_args()
    main(quick=args.quick, table=args.table)

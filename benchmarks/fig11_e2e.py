"""Paper Fig 11: end-to-end sparse inference latency, dense vs n:m:g.

Measured on CPU/XLA at a reduced BERT scale (the TPU-scale picture is the
dry-run roofline).  Reports prefill latency for batch x seq, dense weights
vs GroupedNM FFN weights at several sparsities.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs import get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import GroupedNMTensor
from repro.core.sparsifiers import GroupedNMSparsifier
from repro.models import forward, init_lm, logits_of


def main(quick=False):
    cfg = get_smoke("bert-base-sten").scaled(
        d_model=256, d_ff=1024, n_layers=4, n_heads=8, head_dim=32,
        vocab=4096, dtype="float32",
    )
    B, S = (2, 64) if quick else (4, 128)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)

    @jax.jit
    def infer(p, t):
        h, _ = forward(p, cfg, t, remat="none")
        return logits_of(p, cfg, h[:, -1:])

    t_dense = time_fn(infer, params, toks)
    print("weights,us_per_batch,speedup")
    print(f"dense,{t_dense * 1e6:.0f},1.00")

    for n, m, g in [(2, 4, 16), (1, 4, 16), (1, 10, 4)]:
        sb = SparsityBuilder()
        sb.set_weight("*mlp.w*", GroupedNMSparsifier(n, m, g, gr=16,
                                                     sparse_dim=0),
                      GroupedNMTensor)
        sp = sb.sparsify_params(params)
        t_sp = time_fn(infer, sp, toks)
        print(f"nmg-{n}:{m}:{g},{t_sp * 1e6:.0f},{t_dense / t_sp:.2f}")


if __name__ == "__main__":
    main()

"""Sparse inference serving (paper Fig 11 scenario): batch-serve a model
whose FFN weights are stored in the n:m:g layout, comparing dense vs sparse
latency.

    PYTHONPATH=src python examples/sparse_serve.py [--arch bert-base-sten]
"""

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    args = ap.parse_args()

    base = ["--arch", args.arch, "--batch", "4", "--prompt-len", "32",
            "--gen-len", "12"]
    if not args.full:
        base.append("--smoke")
    print("== dense ==")
    serve_mod.main(base)
    print("== n:m:g 1:4:16 ==")
    serve_mod.main(base + ["--sparse", "--nm", "1:4:16"])


if __name__ == "__main__":
    main()

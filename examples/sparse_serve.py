"""Sparse inference serving (paper Fig 11 scenario): serve a model whose
FFN weights are stored in the n:m:g layout, comparing dense vs sparse
latency — first as the classic one-shot batch, then through the
continuous-batching engine (`repro.serve`) with a queue of requests.

    PYTHONPATH=src python examples/sparse_serve.py [--arch bert-base-sten]
"""

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    args = ap.parse_args()

    base = ["--arch", args.arch, "--batch", "4", "--prompt-len", "32",
            "--gen-len", "12"]
    if not args.full:
        base.append("--smoke")
    print("== one-shot: dense ==")
    serve_mod.main(base)
    print("== one-shot: n:m:g 1:4:16 ==")
    serve_mod.main(base + ["--sparse", "--nm", "1:4:16"])
    print("== continuous batching: dense vs 1:4:16, 8 queued requests ==")
    serve_mod.main(base + ["--engine", "--sparse", "--nm", "1:4:16",
                           "--requests", "8", "--max-slots", "4"])


if __name__ == "__main__":
    main()

"""Extensibility demo (paper §3.1): add a brand-new sparsity layout — a
diagonal-band format — with one class + one sparsifier registration + one
operator implementation, then use it inside a model.

    PYTHONPATH=src python examples/custom_layout.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import sten
from repro.core.layouts import DenseTensor, SparsityLayout, register_layout
from repro.core.sparsifiers import Sparsifier, \
    register_sparsifier_implementation


# 1. the layout: store only diagonals in a band of width 2r+1
@register_layout
class BandTensor(SparsityLayout):
    def __init__(self, diags, r, dense_shape):
        self.diags = diags          # [2r+1, n]
        self.r = r
        self.dense_shape = dense_shape

    @property
    def shape(self):
        return tuple(self.dense_shape)

    @property
    def dtype(self):
        return self.diags.dtype

    def to_dense(self):
        n = self.dense_shape[0]
        out = jnp.zeros(self.dense_shape, self.diags.dtype)
        for i, off in enumerate(range(-self.r, self.r + 1)):
            d = jnp.diag(self.diags[i, : n - abs(off)], k=off)
            out = out + d
        return out

    def tree_flatten(self):
        return (self.diags,), (self.r, self.dense_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


# 2. the sparsifier: keep the band
class BandSparsifier(Sparsifier):
    kind = "streaming"

    def __init__(self, r):
        self.r = r

    def mask(self, x, key=None):
        i = jnp.arange(x.shape[0])[:, None]
        j = jnp.arange(x.shape[1])[None, :]
        return jnp.abs(i - j) <= self.r


@register_sparsifier_implementation(BandSparsifier, DenseTensor, BandTensor)
def dense_to_band(sp, x, key=None):
    x = x.to_dense() if hasattr(x, "to_dense") else x
    n = x.shape[0]
    rows = []
    for off in range(-sp.r, sp.r + 1):
        d = jnp.diagonal(x, offset=off)
        rows.append(jnp.pad(d, (0, n - d.shape[0])))
    return BandTensor(jnp.stack(rows), sp.r, tuple(x.shape))


# 3. an optimized operator implementation for the new layout
@sten.register_op_impl("matmul", inp=(BandTensor, DenseTensor),
                       out=DenseTensor)
def band_matmul(a: BandTensor, b):
    b = b.to_dense() if hasattr(b, "to_dense") else b
    n = a.dense_shape[0]
    out = jnp.zeros((n, b.shape[1]), b.dtype)
    for i, off in enumerate(range(-a.r, a.r + 1)):
        ln = n - abs(off)
        d = a.diags[i, :ln]
        if off >= 0:
            out = out.at[:ln].add(d[:, None] * b[off : off + ln])
        else:
            out = out.at[-off : -off + ln].add(d[:, None] * b[:ln])
    return out


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 16))
    band = sten.apply_sparsifier(BandSparsifier(2), x, BandTensor)
    print(f"BandTensor density: "
          f"{float(jnp.mean(band.to_dense() != 0)):.2f}")

    b = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = sten.matmul(band, b)            # dispatches to band_matmul
    want = band.to_dense() @ b
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    print("custom-layout matmul dispatch: OK (max err "
          f"{float(jnp.abs(y - want).max()):.2e})")

    # fallback still covers everything else
    z = sten.relu(band)
    print("fallback relu:", z.shape)


if __name__ == "__main__":
    main()

"""End-to-end driver: sparse fine-tuning of the paper's BERT_BASE-scale
model (~131M parameters) with iterative n:m:g magnitude pruning, exactly the
paper's Fig 8 workflow, on top of the full production substrate (data
pipeline, AdamW, SameFormatSparsifier re-sparsification, checkpointing).

Full run (a few hundred steps of the ~131M model; several hours on 1 CPU,
minutes on accelerators):

    PYTHONPATH=src python examples/sparse_finetune.py --steps 300

CPU-quick variant used by CI/smoke:

    PYTHONPATH=src python examples/sparse_finetune.py --smoke --steps 60
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sten_finetune_ckpt")
    args = ap.parse_args()

    argv = [
        "--arch", "bert-base-sten",
        "--steps", str(args.steps),
        "--batch", "8" if args.smoke else "32",
        "--seq", "64" if args.smoke else "128",
        "--sparsity", "0.75",
        "--gmp", "iterative",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", str(max(10, args.steps // 5)),
    ]
    if args.smoke:
        argv.append("--smoke")
    raise SystemExit(train_mod.main(argv))


if __name__ == "__main__":
    main()

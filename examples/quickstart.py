"""Quickstart: the STen-JAX programming model in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: sparsity layouts, sparsifiers, dispatch, sparse operators,
SparsityBuilder on a model, and the n:m:g kernel (paper §3 + §5).
"""

import jax
import jax.numpy as jnp

from repro import sten
from repro.core.layouts import CsrTensor, FixedMaskTensor, GroupedNMTensor

key = jax.random.PRNGKey(0)

# --- 1. layouts + sparsifiers ------------------------------------------------
x = jax.random.normal(key, (8, 16))
csr = sten.apply_sparsifier(sten.ScalarFractionSparsifier(0.7), x, CsrTensor)
print(f"CSR tensor: shape={csr.shape}, density={csr.density():.2f}")

# --- 2. dispatch: sparse ops just work ---------------------------------------
b = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
y = sten.matmul(csr, b)                      # CSR x dense implementation
print("sparse matmul:", y.shape)

# unsupported ops fall back to dense with a warning (paper §4.4)
_ = sten.relu(csr)

# --- 3. sparse operators: op + output format (paper §3.3) --------------------
sparse_add = sten.sparsified_op(
    jnp.add,
    sten.OutFormat(sten.KeepAll(), None,
                   sten.RandomFractionSparsifier(0.5), CsrTensor),
)
c = sparse_add(jnp.ones((4, 4)), jnp.ones((4, 4)), key=key)
print(f"sparsified add -> {type(c).__name__}, density={c.density():.2f}")

# --- 4. the paper's n:m:g format + kernel ------------------------------------
w = jax.random.normal(key, (64, 32))
w_nmg = sten.dense_to_grouped_nm(w, n=1, m=4, g=16, sparse_dim=0)
act = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
out = sten.linear(act, w_nmg)                 # n:m:g spmm kernel path
err = jnp.abs(out - act @ w_nmg.to_dense()).max()
print(f"n:m:g linear: {out.shape}, max err vs dense {float(err):.2e}")
print(f"n:m:g energy kept: "
      f"{float(sten.energy(w_nmg.to_dense(), w)):.3f}")

# --- 5. sparsify an existing model (paper §3.4) -------------------------------
from repro.configs import get_smoke
from repro.models import init_lm, loss_fn

cfg = get_smoke("bert-base-sten")
params = init_lm(key, cfg)
sb = sten.SparsityBuilder()
sb.set_weight("*mlp.w*", sten.GroupedNMSparsifier(1, 4, 16, sparse_dim=0),
              FixedMaskTensor)
sparse_params, _ = sb.get_sparse_model(params, None or (lambda p, b: None))
n_sparse = sum(isinstance(l, FixedMaskTensor)
               for l in jax.tree_util.tree_leaves(
                   sparse_params,
                   is_leaf=lambda z: isinstance(z, FixedMaskTensor)))
print(f"sparsified {n_sparse} weight tensors in the model")
batch = {
    "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
    "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab),
}
loss, _ = loss_fn(sparse_params, cfg, batch, remat="none")
print(f"sparse model loss: {float(loss):.3f}")
print("quickstart done.")
